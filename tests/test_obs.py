"""The unified telemetry layer: tracer spans (nesting, threads, Chrome
export), metrics (histogram math vs a numpy reference, mergeability,
typed errors under python -O), the Thm-1 distortion monitor (flags an
under-sized k, silent at the prescribed k), and the cross-layer wiring —
one serve replay plus one compressed train run landing rp dispatch spans,
serve tick spans, train steps and ckpt saves on ONE exported timeline."""
import json
import math
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, rp
from repro.obs import (DistortionMonitor, Histogram, MetricsRegistry, Tracer,
                       required_k)


@pytest.fixture(autouse=True)
def _obs_disabled():
    """Every test starts and ends with the module-global session torn
    down — the layer is process-global by design, tests must not leak."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------------------
# tracer: spans, nesting, threads, export
# ---------------------------------------------------------------------------

def test_span_nesting_depths_and_attrs():
    tr = Tracer()
    with tr.span("outer", family="tt") as sp:
        with tr.span("inner"):
            pass
        sp.set(backend="pallas")        # attrs can land mid-region
    tr.instant("marker", step=3)
    evs = tr.events()
    by_name = {e["name"]: e for e in evs}
    assert by_name["inner"]["args"]["depth"] == 1
    assert "depth" not in by_name["outer"]["args"]       # top level
    assert by_name["outer"]["args"] == {"family": "tt", "backend": "pallas"}
    assert by_name["marker"]["ph"] == "i"
    # spans append at EXIT: inner closes before outer
    assert [e["name"] for e in evs] == ["inner", "outer", "marker"]
    assert by_name["outer"]["dur"] >= by_name["inner"]["dur"] >= 0.0


def test_span_nesting_is_isolated_across_threads():
    """Two threads nest concurrently; each gets its own context-local
    stack (depths never mix) and its own tid lane in the shared buffer."""
    tr = Tracer()
    start = threading.Barrier(2)

    def worker(name):
        start.wait()
        for _ in range(25):
            with tr.span(f"{name}.outer"):
                with tr.span(f"{name}.inner"):
                    time.sleep(0)       # encourage interleaving
    ts = [threading.Thread(target=worker, args=(n,)) for n in ("a", "b")]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    evs = tr.events()
    assert len(evs) == 100 and tr.open_spans() == 0
    for e in evs:
        want_depth = 1 if e["name"].endswith(".inner") else 0
        assert e["args"].get("depth", 0) == want_depth, e
    tids = {e["tid"] for e in evs}
    assert len(tids) == 2               # one lane per thread
    for name in ("a", "b"):             # each thread's events share a tid
        assert len({e["tid"] for e in evs
                    if e["name"].startswith(name)}) == 1


def test_chrome_export_schema(tmp_path):
    tr = Tracer()
    with tr.span("s", k=128, dims=(4, 8)):
        tr.instant("i")
    path = tmp_path / "trace.json"
    n = tr.export(path)
    doc = json.loads(path.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert n == len(doc["traceEvents"]) == 2
    assert doc["displayTimeUnit"] == "ms"
    for e in doc["traceEvents"]:
        assert {"name", "ph", "ts", "pid", "tid", "args"} <= set(e)
        assert e["ph"] in ("X", "i")
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
    # events are ts-sorted in the export (instant fired inside the span)
    ts = [e["ts"] for e in doc["traceEvents"]]
    assert ts == sorted(ts)
    # attribute coercion: the tuple became a JSON list
    span = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert span["args"]["dims"] == [4, 8]


def test_export_with_open_span_is_typed_error():
    tr = Tracer()
    cm = tr.span("open")
    cm.__enter__()
    with pytest.raises(ValueError, match="unclosed span"):
        tr.to_chrome()
    with pytest.raises(ValueError, match="unclosed span"):
        tr.clear()
    cm.__exit__(None, None, None)
    assert tr.to_chrome()["traceEvents"][0]["name"] == "open"


# ---------------------------------------------------------------------------
# metrics: histogram math, merge, typed errors
# ---------------------------------------------------------------------------

def test_histogram_percentiles_match_numpy_within_bucket_width():
    """Bucket-interpolated percentiles vs the numpy reference on the raw
    samples: exact to within the width of the bucket holding the rank."""
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=4.0, sigma=1.0, size=4000)
    bounds = tuple(float(b) for b in np.geomspace(1.0, 1e4, 40))
    h = Histogram("h", bounds)
    for s in samples:
        h.observe(float(s))
    for p in (10.0, 50.0, 90.0, 99.0):
        ref = float(np.percentile(samples, p))
        got = h.percentile(p)
        i = int(np.searchsorted(bounds, ref))
        lo = 0.0 if i == 0 else bounds[i - 1]
        hi = bounds[min(i, len(bounds) - 1)]
        assert lo - 1e-9 <= got <= hi + 1e-9, (p, got, ref, lo, hi)
    assert h.mean == pytest.approx(float(np.mean(samples)))
    # p=0 interpolates to the lower edge of the first occupied bucket
    first = next(i for i, c in enumerate(h.counts) if c)
    assert h.percentile(0.0) == (0.0 if first == 0 else bounds[first - 1])
    assert Histogram("e", (1.0,)).percentile(50.0) == 0.0   # empty
    # overflow reports the last finite edge (deliberate under-estimate)
    h2 = Histogram("h2", (10.0,))
    h2.observe(1e9)
    assert h2.percentile(99.0) == 10.0


def test_histogram_merge_matches_single_stream():
    bounds = (10.0, 100.0, 1000.0)
    a, b, ref = (Histogram("m", bounds) for _ in range(3))
    rng = np.random.default_rng(1)
    for i, s in enumerate(rng.uniform(1.0, 2000.0, size=500)):
        (a if i % 2 else b).observe(float(s))
        ref.observe(float(s))
    a.merge(b)
    assert a.counts == ref.counts and a.count == ref.count
    assert a.percentile(99.0) == ref.percentile(99.0)
    with pytest.raises(ValueError, match="bounds differ"):
        a.merge(Histogram("m", (5.0, 50.0)))


def test_metrics_registry_typed_errors_and_merge():
    reg = MetricsRegistry()
    reg.counter("c").inc(3)
    reg.gauge("g").set(7.5)
    reg.histogram("h", (10.0, 100.0)).observe(42.0)
    reg.event("ev", step=1)
    with pytest.raises(ValueError, match="monotonic"):
        reg.counter("c").inc(-1)
    with pytest.raises(ValueError, match="already registered as"):
        reg.gauge("c")
    with pytest.raises(ValueError, match="different bounds"):
        reg.histogram("h", (1.0, 2.0))
    with pytest.raises(ValueError, match="positive"):
        reg.histogram("neg", (-1.0, 2.0))
    with pytest.raises(ValueError, match="ascending"):
        reg.histogram("asc", (2.0, 1.0))
    other = MetricsRegistry()
    other.counter("c").inc(2)
    other.gauge("g").set(9.0)
    other.histogram("h", (10.0, 100.0)).observe(7.0)
    other.event("ev", step=2)
    reg.merge(other)
    assert reg.counter("c").value == 5
    assert reg.gauge("g").value == 9.0          # last write wins
    assert reg.histogram("h", (10.0, 100.0)).count == 2
    assert [e["step"] for e in reg.events] == [1, 2]


def test_metrics_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.histogram("h", (10.0,)).observe(3.0)
    reg.event("boom", why="test")
    path = tmp_path / "m.jsonl"
    assert reg.write_jsonl(path) == 3
    rows = obs.read_jsonl(path)
    assert {r["type"] for r in rows} == {"counter", "histogram", "event"}
    hist = next(r for r in rows if r["type"] == "histogram")
    assert {"bounds", "counts", "sum", "count", "p50", "p99"} <= set(hist)


def test_obs_typed_errors_survive_python_O():
    """The export/bounds misuse checks are typed ValueErrors, not asserts
    — they must still fire under python -O."""
    import os
    import subprocess
    import sys
    code = """
from repro.obs import DistortionMonitor, Histogram, Tracer
tr = Tracer()
cm = tr.span("open")
cm.__enter__()
try:
    tr.to_chrome()
except ValueError as e:
    assert "unclosed span" in str(e), e
else:
    raise SystemExit("open-span export not caught under -O")
cm.__exit__(None, None, None)
try:
    Histogram("h", (-1.0, 2.0))
except ValueError as e:
    assert "positive" in str(e), e
else:
    raise SystemExit("negative bounds not caught under -O")
try:
    Histogram("h", (2.0, 1.0))
except ValueError as e:
    assert "ascending" in str(e), e
else:
    raise SystemExit("non-ascending bounds not caught under -O")
try:
    DistortionMonitor(eps=0.0, delta=0.1)
except ValueError as e:
    assert "eps" in str(e), e
else:
    raise SystemExit("bad eps not caught under -O")
print("O_SAFE_OK")
"""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    res = subprocess.run([sys.executable, "-O", "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0 and "O_SAFE_OK" in res.stdout, (
        res.stdout, res.stderr)


# ---------------------------------------------------------------------------
# the module-global session + no-op fast path
# ---------------------------------------------------------------------------

def test_disabled_fast_path_returns_shared_noops():
    assert not obs.enabled()
    assert obs.span("x", a=1) is obs.span("y")          # one shared object
    assert obs.counter("c") is obs.histogram("h")
    with obs.span("x") as sp:
        assert sp.set(a=1) is sp
    obs.instant("i")
    obs.event("e")
    obs.counter("c").inc()
    obs.histogram("h").observe(1.0)                      # all inert
    ctx = obs.enable()
    try:
        assert obs.enabled() and obs.get_tracer() is ctx.tracer
        assert obs.span("x") is not obs.span("x")        # real scopes now
        obs.counter("c").inc(2)
        assert ctx.metrics.counter("c").value == 2
    finally:
        assert obs.disable() is ctx
    assert obs.get_context() is None


def test_capture_exports_on_exit(tmp_path):
    tp, mp = tmp_path / "t.json", tmp_path / "m.jsonl"
    with obs.capture(trace_path=tp, metrics_path=mp):
        with obs.span("region", tag="x"):
            obs.counter("n").inc()
    assert not obs.enabled()
    assert json.loads(tp.read_text())["traceEvents"][0]["name"] == "region"
    assert obs.read_jsonl(mp)[0]["name"] == "n"


# ---------------------------------------------------------------------------
# distortion monitor vs Thm 1
# ---------------------------------------------------------------------------

def _feed(mon, k, n_samples=256, seed=0):
    """Stream real TT-RP sketch distortions ||Sx||^2/||x||^2 at width k."""
    dims, rank = (4, 8, 8), 2
    op = rp.make_projector(
        rp.ProjectorSpec(family="tt", k=k, dims=dims, rank=rank),
        jax.random.PRNGKey(7))
    xs = jax.random.normal(jax.random.PRNGKey(8),
                           (n_samples, int(np.prod(dims))))
    ys = np.asarray(rp.project(op, xs, backend="xla"))
    xs = np.asarray(xs)
    for i in range(n_samples):
        mon.observe_norms("tt", 3, k, float(xs[i] @ xs[i]),
                          float(ys[i] @ ys[i]), rank=rank)


def test_required_k_matches_chebyshev():
    # tt, N=3, R=2: c = 3(1 + 2/R)^(N-1) - 1 = 11
    assert required_k("tt", 3, rank=2, eps=0.5, delta=0.1) == \
        math.ceil(11 / (0.1 * 0.25)) == 440
    with pytest.raises(ValueError, match="eps"):
        required_k("tt", 3, rank=2, eps=0.0, delta=0.1)


def test_distortion_monitor_flags_undersized_k_only():
    """k=8 (<< the 440 Thm-1 prescribes for eps=0.5, delta=0.1) must
    alert; k=512 (above it) must stay silent on the same stream."""
    alerts = []
    mon = DistortionMonitor(eps=0.5, delta=0.1, min_samples=64,
                            on_alert=alerts.append)
    _feed(mon, k=8)
    assert len(alerts) == 1, "undersized k must alert exactly once"
    al = alerts[0]
    assert (al.family, al.order, al.k) == ("tt", 3, 8)
    assert al.out_rate > al.delta and al.k_required == 440
    ev = al.as_event()
    assert ev["name"] == "distortion.alert" and ev["k"] == 8

    mon2 = DistortionMonitor(eps=0.5, delta=0.1, min_samples=64,
                             on_alert=alerts.append)
    _feed(mon2, k=512)
    assert len(alerts) == 1, "paper-prescribed k must not alert"
    rows = mon2.summary()
    assert len(rows) == 1 and not rows[0]["alerted"]
    assert rows[0]["out_rate"] <= 0.1


def test_distortion_alert_routes_to_metrics_and_trace():
    """enable(distortion=...) auto-wires alerts into the metrics event
    log AND the trace as an instant."""
    ctx = obs.enable(distortion=DistortionMonitor(eps=0.5, delta=0.1,
                                                  min_samples=64))
    try:
        _feed(ctx.distortion, k=8)
    finally:
        obs.disable()
    evs = [e for e in ctx.metrics.events if e["name"] == "distortion.alert"]
    assert len(evs) == 1 and evs[0]["k_required"] == 440
    instants = [e for e in ctx.tracer.events()
                if e["ph"] == "i" and e["name"] == "distortion.alert"]
    assert len(instants) == 1


def test_distortion_monitor_typed_errors():
    with pytest.raises(ValueError, match="eps"):
        DistortionMonitor(eps=-1.0, delta=0.1)
    with pytest.raises(ValueError, match="delta"):
        DistortionMonitor(eps=0.5, delta=1.0)
    with pytest.raises(ValueError, match="min_samples"):
        DistortionMonitor(eps=0.5, delta=0.1, min_samples=0)
    mon = DistortionMonitor(eps=0.5, delta=0.1)
    with pytest.raises(ValueError, match="k"):
        mon.observe("tt", 3, 0, 1.0)


# ---------------------------------------------------------------------------
# cross-layer wiring
# ---------------------------------------------------------------------------

def test_train_loop_straggler_emits_exactly_one_event_per_straggler():
    """The [straggler] log line and the train.straggler event are 1:1 —
    the forced spike at step 8 produces its event, and no step produces
    more than one."""
    from repro.data import DataConfig, SyntheticLM
    from repro.runtime import train_loop

    def step_fn(state, batch):
        time.sleep(0.25 if int(state["step"]) == 8 else 0.02)
        return ({"w": state["w"] + 1.0, "step": state["step"] + 1},
                {"loss": jnp.sum(state["w"])})

    data = SyntheticLM(DataConfig(vocab=16, seq_len=8, global_batch=2))
    logs = []
    ctx = obs.enable()
    try:
        train_loop.run(step_fn, {"w": jnp.zeros(()), "step": jnp.int32(0)},
                       data, train_loop.LoopConfig(total_steps=12),
                       log=logs.append)
    finally:
        obs.disable()
    evs = [e for e in ctx.metrics.events if e["name"] == "train.straggler"]
    log_lines = [l for l in logs if l.startswith("[straggler]")]
    assert len(evs) == len(log_lines)       # routed 1:1, log strings kept
    assert any(e["step"] == 8 and e["zscore"] > 4.0 for e in evs)
    assert len([e for e in evs if e["step"] == 8]) == 1
    # the trace got the same markers as instants, on the step timeline
    spans = [e for e in ctx.tracer.events() if e["name"] == "train.step"]
    assert len(spans) == 12
    assert all(e["args"]["step"] in range(12) for e in spans)


def test_resume_and_fallback_route_through_event_layer(tmp_path):
    """[resume]/[fallback] keep their log strings AND land as events with
    the restored/requested steps attached."""
    from repro.ckpt import checkpointer
    from repro.data import DataConfig, SyntheticLM
    from repro.runtime import train_loop
    from repro.runtime.resilience import flip_byte

    def step_fn(state, batch):
        return {"w": state["w"] + 1.0}, {"loss": jnp.sum(state["w"])}

    data = SyntheticLM(DataConfig(vocab=16, seq_len=8, global_batch=2))
    cfg = train_loop.LoopConfig(total_steps=8, ckpt_dir=str(tmp_path),
                                ckpt_every=2, async_ckpt=False)
    train_loop.run(step_fn, {"w": jnp.zeros(())}, data, cfg,
                   log=lambda s: None)
    flip_byte(f"{tmp_path}/step_{8:010d}/arr_0.npy")   # corrupt newest
    logs = []
    ctx = obs.enable()
    try:
        cfg2 = train_loop.LoopConfig(total_steps=10,
                                     ckpt_dir=str(tmp_path), ckpt_every=5)
        train_loop.run(step_fn, {"w": jnp.zeros(())}, data, cfg2,
                       log=logs.append)
    finally:
        obs.disable()
    assert any(l.startswith("[resume]") for l in logs)  # strings kept
    names = [e["name"] for e in ctx.metrics.events]
    assert names.count("ckpt.fallback") == 1
    assert names.count("ckpt.resume") == 1
    fb = next(e for e in ctx.metrics.events if e["name"] == "ckpt.fallback")
    assert fb["step_requested"] == 8 and fb["step_restored"] == 6
    # the restore span carries the fallback as an attribute
    restores = [e for e in ctx.tracer.events()
                if e["name"] == "ckpt.restore"]
    assert len(restores) == 1
    assert restores[0]["args"]["fallback_from"] == 8
    assert restores[0]["args"]["step"] == 6
    assert checkpointer.latest_step(tmp_path) == 10


def test_shared_timeline_serve_plus_train(tmp_path):
    """The acceptance criterion: ONE enabled session spanning a serve
    replay and an 8-step compressed train run with async checkpoints
    exports a single Perfetto-loadable trace where rp dispatch spans,
    serve tick spans, train steps and ckpt saves share the timeline (ckpt
    saves on the writer thread's own lane), plus parseable JSONL metrics."""
    from repro.core.sketch import SketchConfig
    from repro.data import DataConfig, SyntheticLM
    from repro.optim import AdamWConfig, adamw
    from repro.optim.compress import SketchCompressor
    from repro.runtime import train_loop
    from repro.serve import ServeConfig, SketchServer, replay, synth_trace

    tp, mp = tmp_path / "trace.json", tmp_path / "metrics.jsonl"
    with obs.capture(trace_path=tp, metrics_path=mp) as ctx:
        # -- serve replay -------------------------------------------------
        spec = rp.ProjectorSpec(family="tt", k=128, dims=(4, 8, 8), rank=2)
        srv = SketchServer(ServeConfig(max_batch=4, backend="xla",
                                       ingest=False))
        replay(srv, synth_trace(16, [(spec, 0)], seed=2))
        # -- 8-step sketch-compressed train with async ckpts -------------
        comp = SketchCompressor(SketchConfig(family="tt", k=64, rank=2,
                                             bucket_elems=256,
                                             dims=(4, 8, 8)))
        ocfg = AdamWConfig(clip_norm=1.0)
        params = {"w": jnp.ones((256,))}
        opt = adamw.init_state(params, ocfg)
        ef = comp.init_state(params)

        def step_fn(state, batch):
            g = {"w": jnp.ones((256,)) * 0.01}
            g_hat, new_ef, m = comp.compress(g, state["ef"],
                                             step=int(state["opt"]["count"]))
            p, new_opt, _ = adamw.update(state["params"], g_hat,
                                         state["opt"], 1e-3, ocfg)
            return ({"params": p, "opt": new_opt, "ef": new_ef},
                    {"loss": jnp.sum(p["w"] * p["w"]), **m})

        train_loop.run(step_fn, {"params": params, "opt": opt, "ef": ef},
                       data=SyntheticLM(DataConfig(vocab=16, seq_len=8,
                                                   global_batch=2)),
                       cfg=train_loop.LoopConfig(total_steps=8,
                                                 ckpt_dir=str(tmp_path / "ck"),
                                                 ckpt_every=4),
                       log=lambda s: None)
    doc = json.loads(tp.read_text())
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"rp.project", "serve.tick", "train.step", "ckpt.save"} <= names
    # one pid, ckpt saves on the async writer's OWN lane of that timeline
    assert len({e["pid"] for e in evs}) == 1
    save_tids = {e["tid"] for e in evs if e["name"] == "ckpt.save"}
    step_tids = {e["tid"] for e in evs if e["name"] == "train.step"}
    assert save_tids and save_tids.isdisjoint(step_tids)
    # serve tick spans carry the lane tags; dispatch spans the route tags
    tick = next(e for e in evs if e["name"] == "serve.tick")
    assert {"batch", "family", "k", "structure"} <= set(tick["args"])
    proj = next(e for e in evs if e["name"] == "rp.project")
    assert {"family", "structure", "backend", "pipeline"} <= set(proj["args"])
    rows = obs.read_jsonl(mp)
    assert any(r["type"] == "histogram" and r["name"] == "serve/queue_delay_us"
               for r in rows)
    assert any(r["type"] == "counter" and r["name"] == "serve/requests_done"
               for r in rows)
    assert ctx.metrics.counter("serve/requests_done").value == 16
