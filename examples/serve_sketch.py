"""Serving quickstart: sketch ingestion + JL nearest-neighbour retrieval.

Submits a mixed stream of TT / CP / dense payloads to the sketch-serving
engine (dynamic batching: one kernel dispatch per tick), stores the
resulting k-dim sketches, then answers top-m similarity queries ENTIRELY in
the compressed domain — and checks recall@m against exact dense distances
computed from the original (d^N-sized) inputs, which the server never saw.

Run: PYTHONPATH=src python examples/serve_sketch.py
"""
import jax
import numpy as np

from repro import rp
from repro.core.formats import random_cp, random_tt
from repro.serve import ServeConfig, SketchServer, SketchStore

N_ITEMS = 96          # stored corpus
N_QUERIES = 8         # retrieval probes
TOP_M = 5

spec = rp.ProjectorSpec(family="tt", k=256, dims=(8, 16, 16), rank=2)
server = SketchServer(ServeConfig(max_batch=16, flush_us=500.0),
                      SketchStore(spec))

# -- ingest: mixed-structure payloads through the dynamic batcher ---------
key = jax.random.PRNGKey(0)
dense = []          # ground-truth dense copies (the server keeps none)
reqs = []           # store ids are TICK order, not submission order —
                    # keep the requests to map between the two
for i in range(N_ITEMS):
    sub = jax.random.fold_in(key, i)
    if i % 3 == 0:
        x = random_tt(sub, spec.dims, rank=2 + i % 3)
    elif i % 3 == 1:
        x = random_cp(sub, spec.dims, rank=2 + i % 3)
    else:
        x = jax.random.normal(sub, spec.dims)
    dense.append(np.asarray(x.full() if hasattr(x, "full") else x).ravel())
    reqs.append(server.submit(x, spec, now=i * 100.0))
# plant a near-duplicate of each query item: its true nearest neighbour
# by a wide margin, so sketch-space retrieval MUST surface it
twin_reqs = []
for qi in range(N_QUERIES):
    noise = 0.01 * np.random.default_rng(qi).standard_normal(len(dense[qi]))
    twin = (dense[qi] + noise).astype(np.float32)
    dense.append(twin)
    r = server.submit(twin.reshape(spec.dims), spec,
                      now=(N_ITEMS + qi) * 100.0)
    reqs.append(r)
    twin_reqs.append(r)
server.drain((N_ITEMS + N_QUERIES) * 100.0)
sub_of = {r.store_id: i for i, r in enumerate(reqs)}    # store id -> item
rep = server.stats()
print(f"ingested {rep['requests_done']} payloads in {rep['ticks']} ticks "
      f"(occupancy {rep['occupancy_mean']:.2f}, "
      f"cache hit rate {rep['cache']['hit_rate']:.1%})")
print(f"store: {rep['store_size']} x k={spec.k} sketches, "
      f"{rep['store_bytes'] / 1024:.1f} KiB vs "
      f"{len(dense) * spec.input_size * 4 / 1024:.1f} KiB dense")

# -- retrieve: top-m in sketch space vs exact dense distances -------------
D = np.stack(dense)                                   # (N, prod(dims))
hits = total = twins = 0
for qi in range(N_QUERIES):
    res = server.query(server.store.get(reqs[qi].store_id), TOP_M)
    d2 = ((D - D[qi]) ** 2).sum(1)                    # exact, dense
    exact = set(np.argsort(d2, kind="stable")[:TOP_M].tolist())
    got = set(sub_of[int(i)] for i in res.ids)
    hits += len(exact & got)
    total += TOP_M
    twins += int(twin_reqs[qi].store_id in set(int(i) for i in res.ids))
print(f"recall@{TOP_M} vs exact dense distances: {hits / total:.2f} "
      f"(JL eps bound {res.eps:.2f} @ delta={res.delta}; random Gaussian "
      f"corpus distances concentrate, so ties rank noisily)")
print(f"planted near-duplicate found in top-{TOP_M}: "
      f"{twins}/{N_QUERIES} queries")

# -- error bars: the Thm-1 bound on one pairwise estimate -----------------
pw = server.pairwise([reqs[0].store_id], [reqs[1].store_id])
true = float(((D[0] - D[1]) ** 2).sum())
print(f"pair (0,1): sketch d2={pw.dist2[0]:.1f}, true d2={true:.1f}, "
      f"bound [{pw.dist2_lo[0]:.1f}, "
      f"{'inf' if np.isinf(pw.dist2_hi[0]) else f'{pw.dist2_hi[0]:.1f}'}]")
