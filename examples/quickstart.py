"""Quickstart: the paper's tensorized random projections via the unified
`repro.rp` API — one spec, one factory, one structure-dispatched `project`.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import rp
from repro.core import BatchedTTTensor, random_tt, theory

key = jax.random.PRNGKey(0)

# ---------------------------------------------------------------- setup ----
# A unit-norm order-12 tensor with d=3 (the paper's "medium-order" case):
# as a flat vector this is 3^12 = 531,441 dims — dense Gaussian RP needs a
# k x 531441 matrix; the tensorized maps need a few thousand parameters.
dims = (3,) * 12
x = random_tt(key, dims, rank=10, norm="unit")
x_dense = x.full()
k = 512

# Every family goes through the same spec/registry — adding a new family
# (see PAPERS.md) is one @rp.register_family entry, not a new call-site API.
tt_op = rp.make_projector(
    rp.ProjectorSpec(family="tt", k=k, dims=dims, rank=5),
    jax.random.fold_in(key, 1))
cp_op = rp.make_projector(
    rp.ProjectorSpec(family="cp", k=k, dims=dims, rank=25),
    jax.random.fold_in(key, 2))

print(f"registered families: {rp.list_families()}")
print(f"input dim          : {x_dense.size:,}")
print(f"dense JLT params   : {theory.params_rp('gaussian', k, dims):,}")
print(f"f_TT(5)  params    : {tt_op.num_params():,}")
print(f"f_CP(25) params    : {cp_op.num_params():,}")

# ------------------------------------------------------------ projection ---
# rp.project dispatches on the input's structure: TTTensor / CPTensor take
# the structured contraction path, dense tensors and flat vectors are
# auto-tensorized. No per-format method zoo at the call site.
y_tt = rp.project(tt_op, x)              # fast path: input already in TT
y_tt_dense = rp.project(tt_op, x_dense)  # same map, dense input
y_cp = rp.project(cp_op, x)

print(f"\n||x||^2 = 1.0")
print(f"||f_TT(x)||^2  = {float(jnp.sum(y_tt**2)):.4f}  "
      f"(distortion {abs(float(jnp.sum(y_tt**2)) - 1):.4f})")
print(f"||f_CP(x)||^2  = {float(jnp.sum(y_cp**2)):.4f}  "
      f"(distortion {abs(float(jnp.sum(y_cp**2)) - 1):.4f})")
print(f"TT dense/struct paths agree: "
      f"{bool(jnp.allclose(y_tt, y_tt_dense, rtol=1e-4, atol=1e-5))}")

# -------------------------------------------------- theory (Thm 1 / Thm 2) -
print(f"\nThm-1 variance factors (lower = better embedding at same k):")
print(f"  TT rank 5 : {theory.variance_factor('tt', N=12, R=5):8.1f}")
print(f"  CP rank 25: {theory.variance_factor('cp', N=12, R=25):8.1f}   "
      "<- exponential in N: CP is hopeless at high order")

# ------------------------------------------- TPU kernel (order-N sweep) ----
# backend='auto' picks the mode-sweep Pallas kernel on TPU for MXU-aligned
# shapes of ANY order >= 2; 'pallas' forces it (interpret mode on CPU),
# 'xla' forces the einsum path. An order-4 tensorization of the same bucket
# halves the operator vs the order-3 (64, 128, 64) layout — core params
# scale with the SUM of the modes, not their product.
dims4 = (16, 32, 16, 64)          # same 2^19-element bucket, order 4
x4 = jax.random.normal(jax.random.fold_in(key, 3), dims4)
op4 = rp.make_projector(rp.ProjectorSpec(family="tt", k=256, dims=dims4,
                                         rank=2), jax.random.fold_in(key, 4))
op3 = rp.make_projector(rp.ProjectorSpec(family="tt", k=256,
                                         dims=(64, 128, 64), rank=2),
                        jax.random.fold_in(key, 5))
y_kernel = rp.project(op4, x4, backend="pallas")
y_ref = rp.project(op4, x4, backend="xla")
print(f"\norder-4 mode-sweep kernel matches reference: "
      f"{bool(jnp.allclose(y_kernel, y_ref, rtol=1e-4, atol=1e-4))}")
print(f"operator params, same bucket: order-3 {op3.num_params():,} -> "
      f"order-4 {op4.num_params():,}")

# -------------------------- compressed-domain engine (structured batch) ----
# A BATCH of TT-format inputs projects in ONE carry-sweep kernel launch —
# the paper's "apply efficiently to low-rank inputs given in CP or TT
# format" claim, batched: nothing is ever densified, the carry is
# (B, k, R·R~) floats instead of the d^N dense tensor, and the analytic
# speedup over the dense path is theory.struct_speedup.
xb = BatchedTTTensor.stack(
    [random_tt(jax.random.fold_in(key, 10 + i), dims4, rank=4)
     for i in range(8)])
with rp.dispatch_stats() as stats, rp.force_pallas():
    y_struct = rp.project(op4, xb, backend="auto")   # (8, 256), ONE dispatch
y_struct_ref = rp.project(op4, xb, backend="xla")
print(f"\nbatched TT-format projection: {y_struct.shape} from "
      f"{stats.kernel_calls} kernel dispatch (matches einsum refs: "
      f"{bool(jnp.allclose(y_struct, y_struct_ref, rtol=1e-4, atol=1e-4))})")
print(f"analytic dense/struct FLOP ratio at R~=4: "
      f"{theory.struct_speedup('tt', 'tt', 256, dims4, 2, 4):.1f}x")
