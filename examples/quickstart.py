"""Quickstart: the paper's tensorized random projections in 60 lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (GaussianRP, random_tt, sample_cp_rp, sample_tt_rp,
                        theory)
from repro.kernels import tt_project

key = jax.random.PRNGKey(0)

# ---------------------------------------------------------------- setup ----
# A unit-norm order-12 tensor with d=3 (the paper's "medium-order" case):
# as a flat vector this is 3^12 = 531,441 dims — dense Gaussian RP needs a
# k x 531441 matrix; the tensorized maps need a few thousand parameters.
dims = (3,) * 12
x = random_tt(key, dims, rank=10, norm="unit")
x_dense = x.full()
k = 512

tt_op = sample_tt_rp(jax.random.fold_in(key, 1), dims, k, rank=5)
cp_op = sample_cp_rp(jax.random.fold_in(key, 2), dims, k, rank=25)

print(f"input dim          : {x_dense.size:,}")
print(f"dense JLT params   : {theory.params_gaussian_rp(k, dims):,}")
print(f"f_TT(5)  params    : {tt_op.num_params():,}")
print(f"f_CP(25) params    : {cp_op.num_params():,}")

# ------------------------------------------------------------ projection ---
y_tt = tt_op.project_tt(x)          # fast path: input already in TT format
y_tt_dense = tt_op.project(x_dense)  # same map, dense input
y_cp = cp_op.project_tt(x)

print(f"\n||x||^2 = 1.0")
print(f"||f_TT(x)||^2  = {float(jnp.sum(y_tt**2)):.4f}  "
      f"(distortion {abs(float(jnp.sum(y_tt**2)) - 1):.4f})")
print(f"||f_CP(x)||^2  = {float(jnp.sum(y_cp**2)):.4f}  "
      f"(distortion {abs(float(jnp.sum(y_cp**2)) - 1):.4f})")
print(f"TT dense/struct paths agree: "
      f"{bool(jnp.allclose(y_tt, y_tt_dense, rtol=1e-4, atol=1e-5))}")

# -------------------------------------------------- theory (Thm 1 / Thm 2) -
print(f"\nThm-1 variance factors (lower = better embedding at same k):")
print(f"  TT rank 5 : {theory.variance_factor_tt(12, 5):8.1f}")
print(f"  CP rank 25: {theory.variance_factor_cp(12, 25):8.1f}   "
      "<- exponential in N: CP is hopeless at high order")

# ----------------------------------------------- TPU kernel (order-3 path) -
dims3 = (64, 128, 64)
x3 = jax.random.normal(jax.random.fold_in(key, 3), dims3)
op3 = sample_tt_rp(jax.random.fold_in(key, 4), dims3, 256, 2)
y_kernel = tt_project(op3, x3)     # Pallas kernel (interpret=True on CPU)
y_ref = op3.project(x3)
print(f"\nPallas tt_project kernel matches reference: "
      f"{bool(jnp.allclose(y_kernel, y_ref, rtol=1e-4, atol=1e-4))}")
