"""End-to-end training driver: a reduced llama3.2-family model trained for a
few hundred steps on CPU with checkpointing + fault tolerance. The identical
code path scales to the production mesh (see launch/train.py --mesh 16x16).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300] [--big]
"""
import argparse
import dataclasses
import functools

import jax

from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.config import ShapeSpec
from repro.optim import schedule
from repro.runtime import train_loop

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=300)
p.add_argument("--batch", type=int, default=8)
p.add_argument("--seq", type=int, default=128)
p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
p.add_argument("--big", action="store_true",
               help="~100M-param variant (slow on CPU)")
args = p.parse_args()

cfg = reduced(get_config("llama3.2-3b"))
if args.big:  # ~100M params
    cfg = dataclasses.replace(cfg, n_layers=8, d_model=512, n_heads=8,
                              n_kv_heads=4, head_dim=64, d_ff=2048,
                              vocab=32000)
else:         # ~3M params, CPU-friendly
    cfg = dataclasses.replace(cfg, n_layers=4, d_model=128, n_heads=4,
                              n_kv_heads=2, head_dim=32, d_ff=512,
                              vocab=4096)
model = build_model(cfg)
print(f"model: {cfg.name} ({cfg.param_count():,} params)")

mesh = make_host_mesh()
shape = ShapeSpec("train", args.seq, args.batch, "train")
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                              global_batch=args.batch))
with mesh:
    bundle = steps_lib.build_train_step(
        model, mesh, shape,
        lr_fn=functools.partial(schedule.cosine_with_warmup, peak_lr=1e-3,
                                warmup_steps=30, total_steps=args.steps))
    state = steps_lib.init_train_state(model, jax.random.PRNGKey(0))
    state, final = train_loop.run(
        bundle.fn, state, data,
        train_loop.LoopConfig(total_steps=args.steps,
                              ckpt_dir=args.ckpt_dir, ckpt_every=100,
                              log_every=20))
print(f"done at step {final}; checkpoints in {args.ckpt_dir}")
