"""Beyond-paper demo: TT-sketch gradient compression with error feedback.

Compares uncompressed vs sketched+EF training on a small LM and reports the
bytes that would cross the slow cross-pod link per step.

Run: PYTHONPATH=src python examples/sketch_compression.py
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.sketch import SketchConfig
from repro.data import DataConfig, SyntheticLM
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.config import ShapeSpec
from repro.optim import schedule
from repro.optim.compress import SketchCompressor

cfg = reduced(get_config("llama3.2-3b"))
model = build_model(cfg)
mesh = make_host_mesh()
shape = ShapeSpec("t", 64, 8, "train")
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))
lr = functools.partial(schedule.constant, peak_lr=3e-3)


def run(compressor, steps=80):
    with mesh:
        b = steps_lib.build_train_step(model, mesh, shape, lr_fn=lr,
                                       compressor=compressor)
        state = steps_lib.init_train_state(model, jax.random.PRNGKey(0),
                                           compressor=compressor)
        last = {}
        for i in range(steps):
            state, m = b.fn(state, jax.tree.map(jnp.asarray, data.batch(i)))
            last = m
        return last


base = run(None)
# Order-4 tensorization of the same 512-element bucket: the mode-sweep
# kernels handle any order, and the smaller modes shrink the TT operator
# (core params scale with the sum of the modes) at the same 4x wire saving.
scfg = SketchConfig(family="tt", k=128, rank=8, bucket_elems=4 * 4 * 8 * 4,
                    dims=(4, 4, 8, 4))
comp = SketchCompressor(scfg)
smet = run(comp)
print(f"uncompressed final loss : {float(base['loss']):.4f}")
print(f"sketched+EF  final loss : {float(smet['loss']):.4f}")
print(f"link bytes per step     : dense {int(smet['dense_bytes']):,} -> "
      f"sketch {int(smet['sketch_bytes']):,}")
print(f"EF residual norm        : {float(smet['residual_norm']):.3f} (bounded)")
print(f"Thm-1 shrinkage alpha   : {scfg.shrinkage():.4f}")
