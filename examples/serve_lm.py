"""Batched serving example: continuous batching over the decode step with a
reduced mixtral (MoE + sliding-window ring cache).

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.serve import Request, SlotServer
from repro.models import build_model

cfg = reduced(get_config("mixtral-8x22b"))
model = build_model(cfg)
rng = np.random.default_rng(0)
requests = [Request(i, rng.integers(1, cfg.vocab, size=(8,)))
            for i in range(6)]
server = SlotServer(model, slots=3, max_seq=64, eos=None, max_gen=12)
done = server.run(requests)
for r in sorted(done, key=lambda r: r.rid):
    print(f"request {r.rid}: {len(r.generated)} tokens -> {r.generated}")
print(f"completed {len(done)}/{len(requests)} "
      f"(MoE top-2 routing + SWA ring cache exercised)")
